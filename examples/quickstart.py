"""Quickstart: the paper's experiment in miniature.

Trains the paper's MNIST network (784-400-10, tanh) on synthetic MNIST-like
data with all four HF variants and SGD, printing the Fig. 3 comparison
(objective vs outer iteration). Runs on CPU in ~a minute.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.paper_mlp import MNIST_FIG3
from repro.core import HFConfig, hf_init, hf_step
from repro.data import classification_dataset
from repro.models import build_mlp
from repro.optim.first_order import momentum_sgd


def main():
    model = build_mlp(MNIST_FIG3)
    data = classification_dataset(jax.random.PRNGKey(0), n=4096, d=784, n_classes=10)

    results = {}
    for solver in ("gn_cg", "hessian_cg", "hybrid_cg", "bicgstab"):
        cfg = HFConfig(solver=solver, max_cg_iters=10, init_damping=1.0)
        params = model.init(jax.random.PRNGKey(1))
        state = hf_init(params, cfg)
        step = jax.jit(
            lambda p, s: hf_step(
                model.loss_fn, p, s, data, data, cfg,
                model_out_fn=model.logits_fn, out_loss_fn=model.out_loss_fn,
            )
        )
        losses = []
        for _ in range(25):
            params, state, m = step(params, state)
            losses.append(float(m["loss"]))
        results[solver] = losses
        acc = float(model.accuracy(params, data))
        print(f"{solver:12s} final loss {losses[-1]:.4f}  train acc {acc:.3f}")

    # SGD baseline: one "iteration" = one epoch (paper's Fig. 3 convention)
    opt = momentum_sgd(lr=0.1)
    params = model.init(jax.random.PRNGKey(1))
    st = opt.init(params)
    sgd_step = jax.jit(lambda p, s, b: opt.step(model.loss_fn, p, s, b))
    losses = []
    from repro.data.synthetic import minibatches
    for _ in range(25):
        for b in minibatches(data, 64, seed=0):
            params, st, m = sgd_step(params, st, b)
        losses.append(float(model.loss_fn(params, data)))
    results["msgd"] = losses
    print(f"{'msgd':12s} final loss {losses[-1]:.4f}  "
          f"train acc {float(model.accuracy(params, data)):.3f}")

    print("\nobjective vs outer iteration (Fig. 3 left):")
    print("iter  " + "  ".join(f"{k:>11s}" for k in results))
    for i in range(0, 25, 4):
        print(f"{i:4d}  " + "  ".join(f"{results[k][i]:11.4f}" for k in results))


if __name__ == "__main__":
    main()
