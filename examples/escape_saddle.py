"""Paper Figure 2: escaping the saddle of f = 0.5x^2 + 0.25y^4 - 0.5y^2.

SGD, Newton-CG and GN-CG converge to the saddle (0,0); the paper's
Bi-CG-STAB HF finds the negative-curvature direction (0,±1) and reaches a
local minimum f = -0.25.

  PYTHONPATH=src python examples/escape_saddle.py
"""
import jax
import jax.numpy as jnp

from repro.core import HFConfig, hf_init, hf_step


def loss_fn(params, batch):
    x, y = params["x"], params["y"]
    return 0.5 * x**2 + 0.25 * y**4 - 0.5 * y**2 + 0.0 * jnp.sum(batch)


def model_out_fn(params, batch):
    return jnp.stack([params["x"], params["y"] ** 2 / 2.0])


def out_loss_fn(z, batch):
    return 0.5 * z[0] ** 2 + z[1] ** 2 - z[1] + 0.0 * jnp.sum(batch)


BATCH = jnp.zeros((1,))
START = {"x": jnp.asarray(0.9, jnp.float32), "y": jnp.asarray(0.0, jnp.float32)}


def run_hf(solver, jitter):
    cfg = HFConfig(solver=solver, max_cg_iters=10, init_damping=1e-3,
                   krylov_jitter=jitter)
    params, state = dict(START), hf_init(START, cfg)
    step = jax.jit(lambda p, s: hf_step(
        loss_fn, p, s, BATCH, BATCH, cfg,
        model_out_fn=model_out_fn, out_loss_fn=out_loss_fn))
    traj = [(float(params["x"]), float(params["y"]))]
    for _ in range(40):
        params, state, _ = step(params, state)
        traj.append((float(params["x"]), float(params["y"])))
    return params, traj


def main():
    print(f"{'method':14s} {'final (x,y)':>22s} {'f(x,y)':>10s}  escaped?")
    # SGD
    params = dict(START)
    for _ in range(300):
        g = jax.grad(loss_fn)(params, BATCH)
        params = jax.tree_util.tree_map(lambda p, gg: p - 0.1 * gg, params, g)
    f = float(loss_fn(params, BATCH))
    print(f"{'sgd':14s} ({float(params['x']):8.4f},{float(params['y']):8.4f}) "
          f"{f:10.4f}  {'YES' if f < -0.2 else 'no (saddle)'}")
    for solver, jitter in (("gn_cg", 0.0), ("hessian_cg", 1e-3),
                           ("hybrid_cg", 1e-3), ("bicgstab", 1e-3)):
        params, traj = run_hf(solver, jitter)
        f = float(loss_fn(params, BATCH))
        print(f"{solver:14s} ({float(params['x']):8.4f},{float(params['y']):8.4f}) "
              f"{f:10.4f}  {'YES' if f < -0.2 else 'no (saddle)'}")


if __name__ == "__main__":
    main()
