"""Train a (reduced) assigned-architecture LM with distributed HF vs SGD.

  PYTHONPATH=src python examples/train_lm.py --arch qwen2-1.5b --steps 15

Uses the smoke config on CPU; on a TPU pod drop --smoke handling via
repro.launch.train --full with the production mesh.
"""
import argparse

from repro.configs import ARCH_IDS
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=15)
    ap.add_argument("--solvers", nargs="+",
                    default=["bicgstab", "gn_cg", "momentum"])
    args = ap.parse_args()

    final = {}
    for solver in args.solvers:
        print(f"\n=== {args.arch} / {solver} ===")
        _, _, hist = train(
            args.arch, smoke=True, solver=solver, steps=args.steps,
            batch_size=8, seq_len=64, lr=0.3,
        )
        final[solver] = hist[-1]["loss"]
    print("\nfinal losses:", {k: round(v, 4) for k, v in final.items()})


if __name__ == "__main__":
    main()
