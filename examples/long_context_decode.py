"""Long-context single-sequence decode with the sequence-sharded
flash-decode schedule (models/decode_sharded.py) on 8 simulated devices.

This is the long_500k serving pattern: batch=1, so neither batch nor
kv-heads can shard the KV cache — the cache's sequence slots are sharded
over the model axis and the attention partials merge with a logsumexp
combine (two tiny stat all-reduces instead of moving the cache).

  PYTHONPATH=src python examples/long_context_decode.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.models.attention import KVCache, attn_init, decode_attend, init_kv_cache
from repro.models.decode_sharded import sharded_decode_attend


def main():
    cfg = get_smoke_config("granite-3-8b")
    mesh = jax.make_mesh((8,), ("model",))
    p = attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, W, prefill = 1, 4096, 1000

    cache = init_kv_cache(cfg, B, W, jnp.float32)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    kv_shape = (B, prefill, cfg.n_kv_heads, cfg.resolved_head_dim)
    cache = KVCache(
        k=cache.k.at[:, :prefill].set(jax.random.normal(ks[0], kv_shape)),
        v=cache.v.at[:, :prefill].set(jax.random.normal(ks[1], kv_shape)),
        pos=cache.pos.at[:prefill].set(jnp.arange(prefill)),
    )
    cache_sh = KVCache(
        jax.device_put(cache.k, NamedSharding(mesh, P(None, "model"))),
        jax.device_put(cache.v, NamedSharding(mesh, P(None, "model"))),
        jax.device_put(cache.pos, NamedSharding(mesh, P("model"))),
    )

    ref_step = jax.jit(lambda p, x, t, c: decode_attend(p, x, t, c, cfg))
    sh_step = jax.jit(lambda p, x, t, c: sharded_decode_attend(p, x, t, c, cfg, mesh))

    x = jax.random.normal(ks[2], (B, 1, cfg.d_model), jnp.float32)
    t = jnp.asarray(prefill, jnp.int32)
    y_ref, _ = ref_step(p, x, t, cache)
    y_sh, cache_sh = sh_step(p, x, t, cache_sh)
    err = float(jnp.max(jnp.abs(y_ref - y_sh)))
    print(f"sharded vs reference decode max|diff| = {err:.2e}")

    # decode a few tokens, timing the sharded path
    t0 = time.time()
    for i in range(16):
        y_sh, cache_sh = sh_step(p, x, jnp.asarray(prefill + 1 + i, jnp.int32), cache_sh)
    jax.block_until_ready(y_sh)
    print(f"16 sharded decode steps: {time.time()-t0:.3f}s "
          f"(cache {cache_sh.k.nbytes/2**20:.0f} MiB, 1/8 per device)")
    assert err < 1e-3


if __name__ == "__main__":
    main()
