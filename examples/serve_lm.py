"""Batched serving example: prefill + greedy decode with the KV/state cache.

  PYTHONPATH=src python examples/serve_lm.py --arch zamba2-7b
"""
import argparse

from repro.configs import ARCH_IDS
from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=[a for a in ARCH_IDS if a != "whisper-small"],
                    default="qwen2-1.5b")
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()
    gen, _ = serve(args.arch, smoke=True, batch_size=args.batch_size,
                   prompt_len=args.prompt_len, gen_len=args.gen_len)
    print("first generated row:", gen[0].tolist())


if __name__ == "__main__":
    main()
